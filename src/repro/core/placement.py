"""MoE expert placement by co-activation graph partitioning.

The paper's objective — minimize traffic over the slow link subject to
balanced load — applied to expert parallelism: experts that co-fire on the
same token cost a *duplicate token send* when they live on different EP
shards (the token crosses the all-to-all once per distinct destination
shard).  Partitioning the co-activation graph into ``n_shards`` balanced
groups minimizes exactly those duplicate sends; ``moe.dispatch_bytes``
measures the win and the EP layer applies the permutation
(``expert_perm``) at routing time.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .partition import UGraph, partition_indices


@dataclasses.dataclass
class PlacementResult:
    expert_to_shard: np.ndarray      # (E,) shard id per (logical) expert
    perm: np.ndarray                 # (E,) logical expert -> physical slot
    cut_weight: float                # co-activation weight crossing shards
    loads: np.ndarray                # (n_shards,) activation mass


def coactivation_graph(co: np.ndarray, loads: np.ndarray | None = None
                       ) -> UGraph:
    """co: (E, E) symmetric co-activation counts; node weight = expert
    activation mass (diagonal of routing counts) for load balance."""
    E = co.shape[0]
    nw = list((loads if loads is not None else co.sum(1)).astype(float))
    adj = [dict() for _ in range(E)]
    for i in range(E):
        for j in range(E):
            if i != j and co[i, j] > 0:
                adj[i][j] = float(co[i, j])
    return UGraph([max(w, 1e-9) for w in nw], adj)


def place_experts(co: np.ndarray, n_shards: int, *,
                  loads: np.ndarray | None = None, slots_per_shard: int | None
                  = None, epsilon: float = 0.10, seed: int = 1
                  ) -> PlacementResult:
    """Partition experts into ``n_shards`` balanced groups minimizing
    co-activation cut, then lay groups out into contiguous physical slots
    (slot // slots_per_shard == shard), which is what the EP all_to_all
    expects."""
    E = co.shape[0]
    slots = slots_per_shard or -(-E // n_shards)
    g = coactivation_graph(co, loads)
    part = partition_indices(g, [1.0 / n_shards] * n_shards,
                             epsilon=epsilon, seed=seed)
    part = np.array(part)
    # capacity-respecting fixup: shards own at most `slots` experts
    order = np.argsort([-g.nw[i] for i in range(E)])
    counts = np.zeros(n_shards, int)
    final = -np.ones(E, int)
    for i in order:
        s = part[i]
        if counts[s] < slots:
            final[i] = s
            counts[s] += 1
    for i in order:
        if final[i] < 0:
            s = int(np.argmin(counts))
            final[i] = s
            counts[s] += 1
    # physical slots: fill each shard's slot range in expert order
    perm = -np.ones(E, int)
    next_slot = {s: s * slots for s in range(n_shards)}
    for i in range(E):
        s = final[i]
        perm[i] = next_slot[s]
        next_slot[s] += 1
    cut = 0.0
    for i in range(E):
        for j in range(i + 1, E):
            if final[i] != final[j]:
                cut += co[i, j]
    loads_out = np.zeros(n_shards)
    for i in range(E):
        loads_out[final[i]] += g.nw[i]
    return PlacementResult(final, perm, cut, loads_out)


def random_placement(E: int, n_shards: int, seed: int = 0) -> PlacementResult:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(E)
    slots = -(-E // n_shards)
    shard = perm // slots
    return PlacementResult(shard, perm, float("nan"),
                           np.bincount(shard, minlength=n_shards).astype(float))


def synth_coactivation(E: int, k: int, n_tokens: int = 4096, *,
                       n_clusters: int = 4, affinity: float = 0.8,
                       seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic routing trace with clustered expert affinity (tokens pick
    their k experts mostly within one cluster — the structure real MoE
    routers exhibit and the reason partitioned placement wins).
    Returns (co (E,E), idx (n_tokens, k))."""
    rng = np.random.default_rng(seed)
    cluster = rng.integers(0, n_clusters, size=E)
    by_cluster = [np.where(cluster == c)[0] for c in range(n_clusters)]
    idx = np.zeros((n_tokens, k), int)
    for t in range(n_tokens):
        c = rng.integers(n_clusters)
        pool = by_cluster[c]
        for j in range(k):
            if len(pool) and rng.random() < affinity:
                idx[t, j] = rng.choice(pool)
            else:
                idx[t, j] = rng.integers(E)
    co = np.zeros((E, E))
    for t in range(n_tokens):
        u = np.unique(idx[t])
        for a in range(len(u)):
            for b in range(a + 1, len(u)):
                co[u[a], u[b]] += 1
                co[u[b], u[a]] += 1
    return co, idx
