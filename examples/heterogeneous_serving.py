"""Serving scenario: place batched request DAGs on a heterogeneous pair of
pods (big + small over DCN) with each scheduling policy, then run a REAL
reduced-model decode to show the serving loop itself.

Run:  PYTHONPATH=src python examples/heterogeneous_serving.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "granite_3_2b", "--smoke", "--requests", "6",
          "--prompt-len", "24", "--decode-len", "12", "--scheduler", "gp"])
    for pol in ("eager", "dmda", "heft"):
        main(["--requests", "6", "--scheduler", pol])
