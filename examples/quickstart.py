"""Quickstart: the paper's pipeline end to end in ~40 lines.

1. Express a data-flow task graph (the paper's 38-kernel DAG).
2. Weight it with measured/analytic per-class costs (Formula 1/2 ratios).
3. Partition it (the METIS role) and compare against queue schedulers.
4. Execute the winning placement for real through the JAX executor.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.graph import generate_paper_dag
from repro.core.cost import paper_calibrated_model, workload_ratios
from repro.core.dot import to_dot
from repro.core.schedulers import make_policy
from repro.core.simulate import simulate, make_cpu_gpu_platform
from repro.core.executor import JaxExecutor, attach_matrix_kernels

# 1. the task graph: 38 two-input matrix kernels, 75 dependencies
g = generate_paper_dag("matmul")

# 2. node weights per processor class + edge transfer costs (ms)
model = paper_calibrated_model()
g = model.weight_graph(g, {"matmul": 1024})
ratios = workload_ratios(g, ["cpu", "gpu"])
print(f"Formula (1)/(2) targets: R_cpu={ratios['cpu']:.3f} "
      f"R_gpu={ratios['gpu']:.3f}")

# 3. schedule: graph partition vs the queue-based baselines
plat = make_cpu_gpu_platform()          # 3 CPU workers + 1 GPU over PCIe
for name in ("eager", "dmda", "gp"):
    pol = make_policy(name)
    r = simulate(g, pol, plat)
    print(f"{name:6s} makespan={r.makespan_ms:8.2f} ms  "
          f"transfers={r.n_transfers:3d}  placement={dict(r.kernels_per_class)}")

# visualize the partition (open with graphviz: dot -Tpng quickstart.dot)
gp = make_policy("gp")
simulate(g, gp, plat)
open("/tmp/quickstart_partition.dot", "w").write(
    to_dot(g, {k: (0 if v == "cpu" else 1) for k, v in gp.assignment.items()}))
print("partition visualization -> /tmp/quickstart_partition.dot")

# 4. run the placement for real (JAX executor; groups share this CPU here)
inputs = attach_matrix_kernels(g, 256)
ex = JaxExecutor({"cpu": jax.devices()[0], "gpu": jax.devices()[0]})
res = ex.run(g, gp.assignment, inputs)
print(f"real execution: {res.makespan_ms:.1f} ms, "
      f"{res.n_transfers} inter-group transfers")
