"""Fault-tolerance scenario: a heterogeneous 3-group cluster loses a group
mid-run; the monitor detects it and the graph is RE-partitioned with the
surviving groups' measured throughputs (the paper's scheduler made
elastic — its §IV.D offline restriction lifted).

Run:  PYTHONPATH=src python examples/elastic_repartition.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

from repro.core.cost import paper_calibrated_model
from repro.core.graph import generate_dag
from repro.ft.elastic import Heartbeat, HeartbeatMonitor, replan

model = paper_calibrated_model()
g = model.weight_graph(generate_dag(60, op="matmul", seed=11),
                       {"matmul": 512})
for k in g.nodes.values():   # three device groups, heterogeneous speeds
    base = k.costs.get("gpu", 0.0)
    k.costs = {"podA": base, "podB": base * 2.0, "podC": base * 4.0}

mon = HeartbeatMonitor(["podA", "podB", "podC"], timeout_s=5.0)
now = time.time()
for grp, ms in (("podA", 10.0), ("podB", 20.0), ("podC", 40.0)):
    mon.report(Heartbeat(grp, step=1, step_time_ms=ms, t_wall=now))

plan0 = replan(g, mon.step_ms, dead=[], edge_ms=model.transfer_ms)
print("initial targets:", {k: round(v, 3) for k, v in plan0.targets.items()})
print("initial loads_ms:", {k: round(v, 1)
                            for k, v in plan0.stats["loads_ms"].items()})

# podB dies (no heartbeat for > timeout)
for grp, ms in (("podA", 10.0), ("podC", 40.0)):
    mon.report(Heartbeat(grp, step=9, step_time_ms=ms, t_wall=now + 30))
dead = mon.failed(now=now + 30)
print("detected failures:", dead)

plan1 = replan(g, mon.step_ms, dead=dead, edge_ms=model.transfer_ms)
print("replanned targets:", {k: round(v, 3) for k, v in plan1.targets.items()})
print("replanned loads_ms:", {k: round(v, 1)
                              for k, v in plan1.stats["loads_ms"].items()})
assert "podB" not in set(plan1.assignment.values())
print("podB excluded; cut_edges:", plan1.stats["cut_edges"])
