"""Online incremental re-partitioning: the paper's GP scheduler kept live
under a churning serving workload.

A heterogeneous two-pod platform serves request chains.  Requests arrive and
retire one at a time; the :class:`repro.core.online.OnlinePartitioner`
maintains the partition with boundary-local FM refinement, only escalating to
a full repartition when local moves cannot restore balance.  Mid-run the
small pod loses a worker class share (targets shift), exercising the elastic
path.  Finally the :class:`repro.core.arena.SchedulerArena` replays a whole
stream through every policy for comparison.

Run:  PYTHONPATH=src python examples/online_repartition.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.graph import Kernel
from repro.core.online import OnlinePartitioner
from repro.launch.serve import run_arena
from repro.core.arena import format_table

KV = 16 << 20
COSTS = {"big": 8.0, "small": 24.0}

part = OnlinePartitioner({"big": 0.6, "small": 0.4}, epsilon=0.05, seed=1,
                         edge_ms=lambda nb: nb / 6.25e9 * 1e3)


def fmt(rec):
    return (f"{rec.kind:<11s} imb {rec.imbalance_before:.3f}->"
            f"{rec.imbalance_after:.3f}  cut {rec.cut_before:.1f}->"
            f"{rec.cut_after:.1f}ms  ({rec.reason})")


# -- request arrivals: chains of decode chunks ------------------------------
for rid in range(6):
    prev = None
    for c in range(4):
        name = f"r{rid}.d{c}"
        deps = [(prev, KV)] if prev else []
        rec = part.add_task(Kernel(name, op="decode", costs=dict(COSTS),
                                   out_bytes=KV), deps)
        prev = name
print("after 6 arrivals:", fmt(part.history[-1]))
print("  loads:", {k: round(v, 1) for k, v in part.loads().items()},
      " cut_ms:", round(part.cut(), 2))

# -- retirements: the oldest requests finish --------------------------------
for rid in range(3):
    for c in range(4):
        part.retire_task(f"r{rid}.d{c}")
print("after 3 retirements:", fmt(part.history[-1]))
print("  loads:", {k: round(v, 1) for k, v in part.loads().items()})

# -- elastic event: the big pod halves (targets shift 60/40 -> 33/67) -------
rec = part.set_targets({"big": 1 / 3, "small": 2 / 3},
                       reason="big pod scale-in")
print("after scale-in:", fmt(rec))
print("  loads:", {k: round(v, 1) for k, v in part.loads().items()},
      " full repartitions:", part.n_full,
      " incremental refines:", part.n_incremental)

# -- full policy-vs-policy stream through the arena -------------------------
print("\nSchedulerArena on a churning request stream (drop at step 3):")
rows, _ = run_arena(12, 6, steps=5, drop_step=3, seed=0)
print(format_table(rows))
