"""End-to-end training example: a ~100M-parameter dense LM trained for a
few hundred steps on this host, with checkpointing + restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ModelConfig, LayerSpec
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DistConfig
from repro.launch.train import train

# ~100M params: 8 layers, d=768, GQA 12:4, tied embeddings
CFG = ModelConfig(
    name="lm-100m", family="dense", d_model=768, n_layers=8, n_heads=12,
    n_kv_heads=4, d_ff=2304, vocab=32000, tie_embeddings=True,
    unit=(LayerSpec("attn", "dense"),),
    activation_dtype="float32", remat=False,
)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_lm100m")
    args = ap.parse_args()
    mesh = make_host_mesh()
    params, _, losses = train(
        CFG, mesh, steps=args.steps, global_batch=args.batch,
        seq_len=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        log_every=20, dist=DistConfig(remat=False))
    print(f"first logged loss {losses[0]:.3f} -> last {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should decrease"
