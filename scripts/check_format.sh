#!/usr/bin/env bash
# Run `ruff format --check` over the incrementally-adopted path list.
#
# scripts/format_paths.txt is the single source of truth for which files are
# format-clean; CI's lint job calls this script, and so can you:
#
#   ./scripts/check_format.sh            # check only (what CI runs)
#   ./scripts/check_format.sh --fix      # rewrite the listed files in place
set -euo pipefail

cd "$(dirname "$0")/.."

mode="--check"
if [[ "${1:-}" == "--fix" ]]; then
  mode=""
fi

# strip comments and blank lines; fail loudly on a listed-but-missing path
paths=()
while IFS= read -r line; do
  line="${line%%#*}"
  line="$(echo "$line" | xargs || true)"
  [[ -z "$line" ]] && continue
  if [[ ! -e "$line" ]]; then
    echo "error: scripts/format_paths.txt lists missing path: $line" >&2
    exit 1
  fi
  paths+=("$line")
done < scripts/format_paths.txt

# shellcheck disable=SC2086
exec ruff format $mode "${paths[@]}"
