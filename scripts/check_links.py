#!/usr/bin/env python
"""Check relative markdown links (and their #anchors) in README.md + docs/.

CI's lint job runs this so a docs reshuffle can never leave dangling
cross-references.  External http(s) links are NOT fetched — only
repo-relative targets are verified, against the working tree:

    python scripts/check_links.py            # exit 1 on any broken link

GitHub-style anchor slugs: lowercase, punctuation stripped, spaces to
hyphens (the rule github.com applies to rendered headings).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    text = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    text = CODE_FENCE.sub("", path.read_text(encoding="utf-8"))
    return {slugify(h) for h in HEADING.findall(text)}


def check_file(path: Path) -> list[str]:
    problems = []
    text = CODE_FENCE.sub("", path.read_text(encoding="utf-8"))
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, frag = target.partition("#")
        dest = (path.parent / ref).resolve() if ref else path
        rel = path.relative_to(ROOT)
        if not dest.exists():
            problems.append(f"{rel}: broken link -> {target}")
            continue
        if frag and dest.suffix == ".md":
            if slugify(frag) not in anchors_of(dest):
                problems.append(f"{rel}: missing anchor -> {target}")
    return problems


def main() -> int:
    files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("**/*.md"))]
    problems = []
    for f in files:
        if f.exists():
            problems.extend(check_file(f))
    for p in problems:
        print(f"[links] FAIL: {p}")
    if problems:
        return 1
    print(f"[links] OK: {len(files)} files checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
